"""Quickstart: IMA-GNN in five minutes.

1. Build a synthetic graph with Cora-like statistics.
2. Run GNN inference through the in-memory-accelerator numerics
   (bit-accurate crossbar DAC/ADC model) and compare to ideal floats.
3. Ask the cost model which execution setting the paper's Eqs. 1-7
   recommend for this workload (the "design guideline").

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, gnn
from repro.core.graph import dataset_like
from repro.kernels.crossbar_mvm import CrossbarNumerics

# 1. a Cora-scale synthetic graph --------------------------------------
g = dataset_like("cora", scale=0.25, seed=0).gcn_normalize()
print(f"graph: {g.n_nodes} nodes, {g.n_edges} edges, "
      f"{g.feature_len}-dim features")
neighbors, weights = g.neighbor_sample(sample=8)

# 2. inference: ideal vs in-memory crossbar numerics --------------------
cfg_ideal = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(64,),
                          out_dim=7, sample=8)
cfg_xbar = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(64,), out_dim=7,
                         sample=8,
                         numerics=CrossbarNumerics(ideal=False))
params = gnn.init_params(jax.random.key(0), cfg_ideal)
x = jnp.asarray(g.features)
nb, wt = jnp.asarray(neighbors), jnp.asarray(weights)

out_ideal = gnn.forward(params, x, nb, wt, cfg_ideal)
out_xbar = gnn.forward(params, x, nb, wt, cfg_xbar)
agree = float((out_ideal.argmax(-1) == out_xbar.argmax(-1)).mean())
err = float(jnp.abs(out_ideal - out_xbar).max() /
            (jnp.abs(out_ideal).max() + 1e-9))
nm = cfg_xbar.numerics
print(f"crossbar-vs-ideal: {agree:.1%} argmax agreement (untrained random "
      f"weights => near-tie logits), {err:.2%} max relative output error "
      f"({nm.in_bits}-bit DAC / {nm.adc_bits}-bit ADC, "
      f"{nm.rows_per_xbar}-row crossbars)")

# 3. the executable design guideline ------------------------------------
stats = g.stats("cora-like")
best, metrics = costmodel.pick_setting(stats)
print("\npaper Eqs. 1-7 on this workload:")
for s, m in metrics.items():
    print(f"  {s:14s} T_compute {m.t_compute:10.3e}s  "
          f"T_comm {m.t_communicate:10.3e}s  T_net {m.t_net:10.3e}s")
print(f"guideline picks: {best}")
