"""Decentralized GNN serving over a device mesh (the paper's Fig. 4b).

Partitions a Collab-like graph into K clusters (one per device), builds the
halo-exchange plan (the paper's bidirectional e_ij communication volume),
and serves node-embedding requests with the shard_map SPMD runtime in both
exchange modes:

  * allgather — the paper-faithful broadcast-within-cluster behavior,
  * alltoall  — beyond-paper: each device ships only the boundary rows its
    peers need (traffic = true e_ij).

Also verifies both against the centralized (single-device, full-graph)
oracle and prints the measured bytes-on-the-wire both modes imply.

Run with multiple fake devices to see real sharding:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/gnn_serve.py --clusters 8
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, gnn
from repro.core.graph import dataset_like
from repro.core.partition import build_local_subgraphs, gather_features, \
    partition
from repro.distributed.halo import build_halo_plan, make_decentralized_forward
from repro.launch.mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clusters", type=int, default=0,
                    help="default: one per device")
    ap.add_argument("--sample", type=int, default=8)
    args = ap.parse_args()

    n_dev = len(jax.devices())
    k = args.clusters or n_dev
    assert k % n_dev == 0 or n_dev == 1, (k, n_dev)

    g = dataset_like("collab", scale=0.002, seed=0).gcn_normalize()
    print(f"graph: {g.n_nodes} nodes, {g.n_edges} edges, "
          f"{g.feature_len}-dim features; {k} clusters on {n_dev} devices")

    # prune halo/send tables to the sample-reachable edges the kernels read,
    # so the printed wire bytes equal the tabulated e_ij
    part = partition(g, k, sample=args.sample)
    sub = build_local_subgraphs(g, part, args.sample)
    plan = build_halo_plan(part)
    feats = gather_features(g, part)                  # [K, n_max, F]

    cfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(64,), out_dim=16,
                        sample=args.sample)
    params = gnn.init_params(jax.random.key(0), cfg)

    # centralized oracle: full-graph forward on one device
    nb, wt = g.neighbor_sample(args.sample)
    oracle = gnn.forward(params, jnp.asarray(g.features), jnp.asarray(nb),
                         jnp.asarray(wt), cfg)

    mesh = make_mesh((n_dev,), ("data",))
    for mode in ("allgather", "alltoall"):
        fwd = make_decentralized_forward(mesh, cfg, plan, part.n_max,
                                         mode=mode)
        out = fwd(params, jnp.asarray(feats), jnp.asarray(sub.neighbors),
                  jnp.asarray(sub.weights))
        # stitch per-cluster outputs back to global node order
        got = np.zeros((g.n_nodes, cfg.out_dim), np.float32)
        o = np.asarray(out)
        for c in range(k):
            nodes = part.local_nodes[c][part.local_mask[c]]
            got[nodes] = o[c][part.local_mask[c]]
        err = np.abs(got - np.asarray(oracle)).max()
        from repro.distributed.traffic import exchange_rows
        rows = exchange_rows(plan, mode, part.n_max)
        traffic = int(rows.sum()) * g.feature_len * 4
        print(f"  {mode:10s} max|err| vs centralized oracle "
              f"{err:.2e}   wire bytes/layer {traffic/1e6:8.2f} MB")

    # per-cluster Eqs. 4/7 prediction for the decentralized plan
    e_ij = part.comm_volume
    print(f"\nhalo volume e_ij (sample-pruned rows shipped/layer): total "
          f"{int(e_ij.sum())}, max per cluster {int(e_ij.sum(1).max())}")
    best, metrics = costmodel.pick_setting(g.stats("collab-like"),
                                           n_clusters=k)
    print(f"cost-model guideline for this graph: {best} "
          f"(T_net centralized {metrics['centralized'].t_net:.3e}s, "
          f"decentralized {metrics['decentralized'].t_net:.3e}s, "
          f"semi {metrics['semi'].t_net:.3e}s)")


if __name__ == "__main__":
    main()
