"""Decentralized GNN serving over a device mesh (the paper's Fig. 4b).

Partitions a Collab-like graph into K clusters (one per device), builds the
halo-exchange plan (the paper's bidirectional e_ij communication volume),
and serves node-embedding requests with the shard_map SPMD runtime in both
exchange modes:

  * allgather — the paper-faithful broadcast-within-cluster behavior,
  * alltoall  — beyond-paper: each device ships only the boundary rows its
    peers need (traffic = true e_ij).

Also verifies both against the centralized (single-device, full-graph)
oracle and prints the measured bytes-on-the-wire both modes imply.

Run with multiple fake devices to see real sharding:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python examples/gnn_serve.py --clusters 8

Bucketed mode (``--buckets auto``) demos the capacity-bucketed ragged
data plane (DESIGN.md §12) on a power-law graph with an edge-balanced
(deliberately node-skewed) partition: per-bucket capacities, padding
waste vs the uniform dense layout, the overlapped vs serialized halo
exchange, and bit-exact parity with the dense plan:

  PYTHONPATH=src python examples/gnn_serve.py --buckets auto

Streaming mode (``--stream N``) instead drives a taxi-style dynamic graph:
``core.taxi.synthetic_stream`` ticks flow into
``repro.streaming.StreamingGNNServer.ingest()``, embeddings refresh
incrementally over the k-hop dirty frontier, and queries serve between
commits (DESIGN.md §9):

  PYTHONPATH=src python examples/gnn_serve.py --stream 12

Technology mode (``--tech``) plans the taxi mixed churn+query workload
over the device-technology bank (DESIGN.md §13) and prints the per-tier
recommendation — e.g. dense ReRAM spokes storing the partition under fast
SRAM cluster heads — plus the Monte-Carlo accuracy bound behind it:

  PYTHONPATH=src python examples/gnn_serve.py --tech
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import costmodel, gnn
from repro.core.graph import dataset_like, random_graph
from repro.core.partition import build_local_subgraphs, gather_features, \
    partition, plan_execution
from repro.distributed.halo import build_halo_plan, make_decentralized_forward
from repro.launch.mesh import make_mesh


def stream_demo(n_ticks: int, sample: int) -> None:
    """End-to-end streaming quickstart: synthetic_stream ticks -> ingest ->
    incremental refresh -> batched query."""
    from repro.core import taxi
    from repro.streaming import StreamingGNNServer

    cfg_t = taxi.TaxiConfig(m=6, n=6)
    n_nodes = 300
    g = random_graph(n_nodes, n_nodes * 6, cfg_t.region, seed=0)
    g = g.gcn_normalize()
    plan = plan_execution(g, "decentralized", backend="jnp", sample=sample,
                          n_clusters=4)
    cfg = gnn.GNNConfig(in_dim=cfg_t.region, hidden_dims=(32,), out_dim=16,
                        sample=sample)
    srv = StreamingGNNServer(plan, cfg, policy="bounded-staleness",
                             max_staleness=4, max_dirty_frac=0.3)
    print(f"streaming: {n_nodes} taxis, {cfg_t.region}-dim demand maps, "
          f"cold refresh {srv.refresh() * 1e3:.1f} ms")

    # the §4.2 demand/supply stream: each tick only part of the map moves
    stream = np.asarray(taxi.synthetic_stream(jax.random.key(0), n_nodes,
                                              n_ticks, cfg_t))
    rng = np.random.default_rng(0)
    feats = np.asarray(g.features)
    for t in range(n_ticks):
        moved = rng.random(n_nodes) < 0.1          # 10% of taxis move
        x_t = feats.copy()
        x_t[moved] = stream[t][moved]
        feats = x_t
        upd = srv.ingest(x_t)
        emb = srv.query(rng.integers(0, n_nodes, 16))
        state = ("commit: recomputed "
                 f"{upd.recompute_fraction * 100:5.1f}% of rows, "
                 f"{upd.seconds * 1e3:6.1f} ms"
                 + (f", shipped {upd.traffic.total_bytes() / 1e3:.1f} kB"
                    if upd.traffic is not None else "")
                 if upd is not None else
                 f"buffered ({srv.pending_ticks} ticks pending)")
        print(f"  tick {t:2d}: {state}; served {len(emb)} lookups")
    srv.flush()
    fracs = [u.recompute_fraction for u in srv.updates if not u.full]
    print(f"{srv.commits} commits ({srv.full_refreshes} full); mean "
          f"incremental recompute fraction "
          f"{float(np.mean(fracs)) if fracs else 1.0:.3f}")


def bucketed_demo(sample: int, buckets, clusters: int) -> None:
    """Capacity-bucketed ragged layout quickstart: skewed partition ->
    pow2 buckets -> overlapped halo exchange -> dense parity."""
    import time

    k = clusters or 16
    g = random_graph(6000, 24000, 16, seed=0).gcn_normalize()
    cfg = gnn.GNNConfig(in_dim=16, hidden_dims=(32,), out_dim=16,
                        sample=sample)
    params = gnn.init_params(jax.random.key(0), cfg)
    plan = plan_execution(g, "decentralized", backend="jnp", sample=sample,
                          n_clusters=k, buckets=buckets,
                          partition_method="edge")
    bp = plan.bucketed
    ls = plan.layout_stats(cfg)
    caps = sorted({(int(bp.n_caps[b]), len(bp.clusters[b]))
                   for b in range(bp.n_buckets)})
    print(f"bucketed: {g.n_nodes} power-law nodes, {k} edge-balanced "
          f"clusters -> {bp.n_buckets} buckets (cap, clusters): {caps}")
    print(f"  padded rows {ls['padded_rows']} vs dense "
          f"{ls['dense_padded_rows']} ({ls['padding_ratio']:.2f}x vs "
          f"{ls['dense_padding_ratio']:.2f}x real)")
    outs = {}
    for overlap in ("overlap", "serial"):
        fwd = plan.make_forward(cfg, overlap=overlap)
        out = fwd(params)
        for o in out:
            o.block_until_ready()
        t = time.perf_counter()
        for o in fwd(params):
            o.block_until_ready()
        dt = time.perf_counter() - t
        outs[overlap] = plan.scatter(out)
        print(f"  {overlap:8s} halo exchange: {dt * 1e3:7.2f} ms/forward")
    dense = plan_execution(g, "decentralized", backend="jnp",
                           sample=sample, n_clusters=k,
                           partition_method="edge")
    ref = dense.scatter(dense.make_forward(cfg)(params))
    print(f"  overlap == serial: "
          f"{np.array_equal(outs['overlap'], outs['serial'])}; "
          f"bucketed == dense: {np.array_equal(outs['overlap'], ref)}")


def tech_demo(sample: int) -> None:
    """Device-technology quickstart (DESIGN.md §13): plan the taxi mixed
    churn+query workload over the technology bank (four pure technologies
    plus the ReRAM-spoke/SRAM-head pair) and print the per-tier pick, the
    Monte-Carlo accuracy bound grounding it, and the noise-tolerance flip."""
    import dataclasses

    from repro.core.graph import TAXI_STATS
    from repro.devices import mvm_error_bounds, technology_table
    from repro.planner import WorkloadProfile, plan

    print(f"{'technology':>10s} {'t_read':>8s} {'e_read':>8s} "
          f"{'bits':>4s} {'sigma':>6s}")
    for t in technology_table():
        print(f"{t['name']:>10s} {t['read_latency_s']:8.1e} "
              f"{t['read_energy_j']:8.1e} {t['cell_bits']:4d} "
              f"{t['noise_sigma']:6.3f}")

    techs = ("sot-mram", "reram", "sram", "fefet", ("reram", "sram"))
    wl = WorkloadProfile(churn=0.01, queries_per_tick=64, sample=sample)
    result = plan(TAXI_STATS, "throughput", workload=wl, technologies=techs)
    c = result.recommended.candidate
    print(f"\ntaxi mixed workload (1% churn/tick, 64 queries/tick): "
          f"{len(result.scored)} candidates, {len(result.frontier)} on the "
          f"Pareto frontier")
    print(f"  recommended plan: {c.key}")
    print(f"    spoke tier (partition storage): {c.spoke_technology}")
    print(f"    head tier  (compute passes):    {c.head_technology}")
    b = mvm_error_bounds(c.head_technology, trials=4)
    print(f"    head-tier MC accuracy bound: mean relative MVM error "
          f"{b.mean_err:.2e}, p99 {b.p99_err:.2e} ({b.trials} trials)")

    # a tight noise tolerance prices the variation bound as infeasible and
    # flips the pick toward the quiet technologies: under the energy
    # objective the lowest-read-energy (but noisy) technology wins until
    # the tolerance rejects it
    loose = plan(TAXI_STATS, "energy", workload=wl, technologies=techs)
    tight = plan(TAXI_STATS, "energy",
                 workload=dataclasses.replace(wl, noise_tolerance=1e-4),
                 technologies=techs)
    cl, ct = loose.recommended.candidate, tight.recommended.candidate
    print(f"  energy objective: head tier {cl.head_technology} -> "
          f"noise_tolerance 1e-4 flips it to {ct.head_technology}")


def _dump_telemetry(args) -> None:
    """Print the demo's span summary and export metrics/trace when asked
    (DESIGN.md §14) — same flags the ``launch.gnn`` CLI takes."""
    if not (args.metrics or args.trace):
        return
    from repro import telemetry
    spans = telemetry.get_tracer().summary()
    if spans:
        print("telemetry spans (count, total ms):")
        for name, s in spans.items():
            print(f"  {name:24s} {s['count']:5d} {s['total_s'] * 1e3:9.2f}")
    if args.metrics:
        n = telemetry.export_metrics(args.metrics)
        print(f"wrote {n} metric lines -> {args.metrics}")
    if args.trace:
        n = telemetry.export_trace(args.trace)
        print(f"wrote {n} span trees -> {args.trace}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clusters", type=int, default=0,
                    help="default: one per device")
    ap.add_argument("--sample", type=int, default=8)
    ap.add_argument("--stream", type=int, default=0, metavar="TICKS",
                    help="run the streaming demo for TICKS synthetic_stream "
                         "ticks instead of the static serving demo")
    ap.add_argument("--buckets", default=None, metavar="auto|N",
                    help="run the capacity-bucketed data-plane demo "
                         "instead of the static serving demo")
    ap.add_argument("--tech", action="store_true",
                    help="run the device-technology planning demo "
                         "(per-tier technology pick for the taxi mixed "
                         "workload; DESIGN.md §13)")
    ap.add_argument("--metrics", metavar="PATH", default=None,
                    help="enable telemetry; export counters/gauges/"
                         "histograms as JSONL to PATH after the demo")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable telemetry; export span trees as JSONL "
                         "to PATH after the demo")
    args = ap.parse_args()

    if args.metrics or args.trace:
        from repro import telemetry
        telemetry.enable()

    try:
        if args.tech:
            return tech_demo(args.sample)
        if args.stream:
            return stream_demo(args.stream, args.sample)
        if args.buckets:
            return bucketed_demo(args.sample,
                                 args.buckets if args.buckets == "auto"
                                 else int(args.buckets), args.clusters)
        _static_demo(args)
    finally:
        _dump_telemetry(args)


def _static_demo(args) -> None:

    n_dev = len(jax.devices())
    k = args.clusters or n_dev
    assert k % n_dev == 0 or n_dev == 1, (k, n_dev)

    g = dataset_like("collab", scale=0.002, seed=0).gcn_normalize()
    print(f"graph: {g.n_nodes} nodes, {g.n_edges} edges, "
          f"{g.feature_len}-dim features; {k} clusters on {n_dev} devices")

    # prune halo/send tables to the sample-reachable edges the kernels read,
    # so the printed wire bytes equal the tabulated e_ij
    part = partition(g, k, sample=args.sample)
    sub = build_local_subgraphs(g, part, args.sample)
    plan = build_halo_plan(part)
    feats = gather_features(g, part)                  # [K, n_max, F]

    cfg = gnn.GNNConfig(in_dim=g.feature_len, hidden_dims=(64,), out_dim=16,
                        sample=args.sample)
    params = gnn.init_params(jax.random.key(0), cfg)

    # centralized oracle: full-graph forward on one device
    nb, wt = g.neighbor_sample(args.sample)
    oracle = gnn.forward(params, jnp.asarray(g.features), jnp.asarray(nb),
                         jnp.asarray(wt), cfg)

    mesh = make_mesh((n_dev,), ("data",))
    for mode in ("allgather", "alltoall"):
        fwd = make_decentralized_forward(mesh, cfg, plan, part.n_max,
                                         mode=mode)
        out = fwd(params, jnp.asarray(feats), jnp.asarray(sub.neighbors),
                  jnp.asarray(sub.weights))
        # stitch per-cluster outputs back to global node order
        got = np.zeros((g.n_nodes, cfg.out_dim), np.float32)
        o = np.asarray(out)
        for c in range(k):
            nodes = part.local_nodes[c][part.local_mask[c]]
            got[nodes] = o[c][part.local_mask[c]]
        err = np.abs(got - np.asarray(oracle)).max()
        from repro.distributed.traffic import exchange_rows
        rows = exchange_rows(plan, mode, part.n_max)
        traffic = int(rows.sum()) * g.feature_len * 4
        print(f"  {mode:10s} max|err| vs centralized oracle "
              f"{err:.2e}   wire bytes/layer {traffic/1e6:8.2f} MB")

    # per-cluster Eqs. 4/7 prediction for the decentralized plan
    e_ij = part.comm_volume
    print(f"\nhalo volume e_ij (sample-pruned rows shipped/layer): total "
          f"{int(e_ij.sum())}, max per cluster {int(e_ij.sum(1).max())}")
    best, metrics = costmodel.pick_setting(g.stats("collab-like"),
                                           n_clusters=k)
    print(f"cost-model guideline for this graph: {best} "
          f"(T_net centralized {metrics['centralized'].t_net:.3e}s, "
          f"decentralized {metrics['decentralized'].t_net:.3e}s, "
          f"semi {metrics['semi'].t_net:.3e}s)")


if __name__ == "__main__":
    main()
