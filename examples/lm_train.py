"""End-to-end LM training driver: ~100M-param model, few hundred steps.

Uses the production train loop (sharding rules, checkpointing, deterministic
resume) on a ~100M-parameter InternLM2-family config. Demonstrates the full
fault-tolerance path: train, kill (simulated fault), resume from the atomic
checkpoint, verify the loss curve continues.

  PYTHONPATH=src python examples/lm_train.py --steps 200
"""
import argparse
import dataclasses
import shutil
import tempfile

import jax

from repro.configs import get_config
from repro.launch.train import TrainConfig, train
from repro.models.config import ModelConfig


def lm_100m() -> ModelConfig:
    """~100M-param GQA decoder (internlm2 family, scaled down)."""
    base = get_config("internlm2-1.8b")
    return dataclasses.replace(
        base, name="internlm2-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, d_ff=2048, vocab=8192)


class _Fault(Exception):
    pass


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fault-at", type=int, default=0,
                    help="simulate a node failure at this step (0 = off)")
    args = ap.parse_args()

    cfg100 = lm_100m()
    n = cfg100.param_count()
    print(f"model: {cfg100.name}, {n/1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")

    ckpt_dir = tempfile.mkdtemp(prefix="lm100m_")
    # monkey-patch the registry so the production driver can resolve it
    import repro.configs as configs
    import repro.launch.train as lt
    orig = configs.get_config
    lt_get = lambda name, smoke=False: cfg100 if name == cfg100.name \
        else orig(name, smoke)
    configs.get_config = lt_get
    lt.get_config = lt_get
    try:
        losses = []
        fired = {"done": False}

        def fault(step):
            if args.fault_at and step == args.fault_at \
                    and not fired["done"]:
                fired["done"] = True
                raise _Fault(f"simulated node failure at step {step}")

        out = train(TrainConfig(arch=cfg100.name, smoke=False,
                                steps=args.steps, batch=args.batch,
                                seq=args.seq, ckpt_dir=ckpt_dir,
                                ckpt_every=25, log_every=20),
                    hooks={"on_step": lambda s, m: losses.append(
                        float(m["loss"])), "fault": fault})
        import math
        ce0, ce1 = losses[0], sum(losses[-10:]) / 10
        print(f"\nfinal: loss {ce0:.3f} -> {ce1:.3f} over "
              f"{out['last_step'] + 1} steps "
              f"(random = {math.log(cfg100.vocab):.3f})")
        assert ce1 < ce0, "no learning"
        if args.fault_at:
            print("fault injected and recovered from checkpoint: OK")
    finally:
        configs.get_config = orig
        lt.get_config = orig
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
